"""Observability subsystem tests: tracer, exporters, metrics satellites,
glossary sync and the perf-trajectory gate.

Covers: span/instant recording over an injectable clock, ring-buffer
bounding with drop accounting, NullTracer no-op compatibility, the
active-tracer escape hatch ``tune.dispatch`` records kernel-config
resolutions through, Chrome trace-event export (structure, lane
metadata, JSON round-trip, validator catching injected corruption),
per-request timeline filtering, a real traced engine run producing >= 1
span per serving phase plus per-request tracks, the metrics satellites
(exact histogram extremes under reservoir eviction, per-path decode-step
counts, first-admission throughput clock), README glossary sync with
``ServeMetrics.summary()``, and ``benchmarks.compare_trajectory``
failing on injected regressions while passing identity/improvement.
"""
import json
import os

import numpy as np
import pytest

from repro.obs import (ENGINE_TRACKS, NULL, SCHEMA_VERSION, NullTracer,
                       Tracer, activate, format_timeline, get_active,
                       record_kernel_config, req_track, save_chrome,
                       set_active, timeline, to_chrome, validate_chrome)
from repro.serve.metrics import Histogram, ServeMetrics

import benchmarks.compare_trajectory as traj


class _Clock:
    """Deterministic manual clock (seconds)."""

    def __init__(self):
        self.t = 0.0

    def tick(self, dt=1e-3):
        self.t += dt

    def __call__(self):
        return self.t


def _tracer():
    return Tracer(clock=_Clock(), capacity=256)


# ---------------------------------------------------------------------------
# tracer core
# ---------------------------------------------------------------------------


class TestTracer:
    def test_span_records_complete_event_with_duration(self):
        tr = _tracer()
        tr.clock.tick(0.001)                     # 1000us after t0
        with tr.span("prefill_chunk", track="engine/prefill", uid=3):
            tr.clock.tick(0.002)                 # body takes 2000us
        (ev,) = tr.events
        assert ev["name"] == "prefill_chunk" and ev["ph"] == "X"
        assert ev["track"] == "engine/prefill"
        assert ev["ts"] == pytest.approx(1000.0)
        assert ev["dur"] == pytest.approx(2000.0)
        assert ev["args"]["uid"] == 3

    def test_span_emits_even_when_body_raises(self):
        tr = _tracer()
        with pytest.raises(RuntimeError):
            with tr.span("tick"):
                raise RuntimeError("boom")
        assert [e["name"] for e in tr.events] == ["tick"]

    def test_instant_and_tick_tagging(self):
        tr = _tracer()
        tr.instant("submit", track=req_track(7), uid=7)
        tr.tick = 4
        tr.instant("admit", track=req_track(7), uid=7)
        a, b = tr.events
        assert a["ph"] == "i" and "tick" not in a["args"]  # tick unset: -1
        assert b["args"]["tick"] == 4

    def test_ring_buffer_bounds_and_counts_drops(self):
        tr = Tracer(clock=_Clock(), capacity=10)
        for i in range(25):
            tr.instant(f"e{i}")
        assert len(tr.events) == 10
        assert tr.dropped == 15 and tr.total == 25
        # newest events win
        assert tr.events[-1]["name"] == "e24"
        tr.clear()
        assert tr.events == [] and tr.dropped == 0

    def test_tracks_engine_lanes_first_in_catalogue_order(self):
        tr = _tracer()
        tr.instant("x", track=req_track(2))
        tr.instant("x", track="engine/sample")
        tr.instant("x", track="engine/tick")
        assert tr.tracks() == ["engine/tick", "engine/sample", "req/2"]
        assert set(ENGINE_TRACKS) >= {"engine/tick", "engine/sample"}

    def test_capacity_must_be_positive(self):
        with pytest.raises(ValueError):
            Tracer(clock=_Clock(), capacity=0)

    def test_null_tracer_is_inert_and_api_compatible(self):
        n = NullTracer()
        with n.span("tick", track="engine/tick", free=3):
            n.instant("admit", uid=1)
        n.emit("x", "i", 0.0, "engine/tick")
        assert n.events == [] and n.tracks() == [] and n.dropped == 0
        assert NULL.now_us() == 0.0


class TestActiveTracer:
    def test_activate_restores_previous(self):
        tr = _tracer()
        assert get_active() is None
        with activate(tr):
            assert get_active() is tr
            with activate(None):
                assert get_active() is None
            assert get_active() is tr
        assert get_active() is None

    def test_record_kernel_config_noop_without_active(self):
        from repro.tune.space import heuristic_config
        cfg = heuristic_config("lut_gemm", b=4, m=64, n=128, mu=4,
                               group_size=32)
        set_active(None)
        record_kernel_config("lut_gemm", "heuristic", cfg)  # must not raise

    def test_dispatch_records_resolution_on_active_tracer(self, monkeypatch):
        from repro.tune.dispatch import kernel_config
        monkeypatch.setenv("REPRO_TUNE", "off")   # deterministic: heuristic
        tr = _tracer()
        with activate(tr):
            cfg = kernel_config("lut_gemm", b=4, m=64, n=128,
                                dtype=np.float32, mu=4, group_size=32)
        (ev,) = tr.events
        assert ev["name"] == "kernel_config:lut_gemm"
        assert ev["track"] == "engine/kernel"
        assert ev["args"]["source"] == "heuristic"
        assert ev["args"]["config"] == cfg.to_dict()
        assert ev["args"]["m"] == 64


# ---------------------------------------------------------------------------
# chrome export + timeline
# ---------------------------------------------------------------------------


def _populated_tracer():
    tr = _tracer()
    tr.tick = 0
    with tr.span("tick", track="engine/tick", running=1):
        tr.clock.tick()
        with tr.span("admission", track="engine/admission"):
            tr.clock.tick()
            tr.instant("admit", track=req_track(0), uid=0)
        tr.instant("token", track=req_track(1), uid=1, pos=5)
    return tr


class TestChromeExport:
    def test_structure_lanes_and_validation(self):
        tr = _populated_tracer()
        obj = to_chrome(tr)
        assert validate_chrome(obj) == []
        evs = obj["traceEvents"]
        procs = {e["args"]["name"] for e in evs
                 if e["ph"] == "M" and e["name"] == "process_name"}
        assert procs == {"engine phases", "requests"}
        lanes = {e["args"]["name"]: (e["pid"], e["tid"]) for e in evs
                 if e["ph"] == "M" and e["name"] == "thread_name"}
        # engine lanes on pid 0, request lanes on pid 1, uid-sorted
        assert lanes["engine/tick"][0] == 0
        assert lanes["req/0"] == (1, 0) and lanes["req/1"] == (1, 1)
        # instants carry thread scope; spans carry dur
        inst = next(e for e in evs if e["ph"] == "i")
        assert inst["s"] == "t"
        span = next(e for e in evs if e["ph"] == "X")
        assert span["dur"] >= 0
        assert obj["otherData"]["schema_version"] == SCHEMA_VERSION

    def test_json_round_trip_still_validates(self, tmp_path):
        tr = _populated_tracer()
        path = save_chrome(tr, str(tmp_path / "trace.json"))
        with open(path) as f:
            loaded = json.load(f)
        assert validate_chrome(loaded) == []
        assert loaded == json.loads(json.dumps(to_chrome(tr),
                                               sort_keys=True))

    def test_validator_catches_injected_corruption(self):
        good = to_chrome(_populated_tracer())
        assert validate_chrome({"nope": 1}) == ["missing traceEvents"]

        bad = json.loads(json.dumps(good))
        bad["otherData"]["schema_version"] = 99
        assert any("schema_version" in e for e in validate_chrome(bad))

        bad = json.loads(json.dumps(good))
        span = next(e for e in bad["traceEvents"] if e["ph"] == "X")
        del span["dur"]
        assert any("bad dur" in e for e in validate_chrome(bad))

        bad = json.loads(json.dumps(good))
        next(e for e in bad["traceEvents"] if e["ph"] == "i")["ph"] = "Z"
        assert any("unexpected ph" in e for e in validate_chrome(bad))

        bad = json.loads(json.dumps(good))
        bad["traceEvents"] = [e for e in bad["traceEvents"]
                              if not (e["ph"] == "M"
                                      and e.get("args", {}).get("name")
                                      == "req/0")]
        assert any("no thread_name" in e for e in validate_chrome(bad))

    def test_timeline_uid_filter_includes_engine_events_naming_it(self):
        tr = _populated_tracer()
        rows = timeline(tr, uid=0)
        names = [r["name"] for r in rows]
        # req/0's own instant plus the engine admission span? admission
        # span has no uid arg -> excluded; 'admit' instant included
        assert "admit" in names
        assert "token" not in names                    # that's uid 1
        all_rows = timeline(tr)
        assert len(all_rows) == len(tr.events)
        assert all_rows == sorted(all_rows, key=lambda r: r["ts_ms"])

    def test_format_timeline_clips_and_reports(self):
        tr = _populated_tracer()
        out = format_timeline(tr, max_rows=2)
        assert "(2 more rows)" in out
        assert "track" in out.splitlines()[0]


# ---------------------------------------------------------------------------
# traced engine run (integration)
# ---------------------------------------------------------------------------


class TestEngineTracing:
    @pytest.fixture(scope="class")
    def traced_run(self):
        import jax
        import jax.numpy as jnp
        from repro.configs import get_reduced
        from repro.models import Model
        from repro.serve import PagedServeEngine, Request

        cfg = get_reduced("opt_6_7b").replace(remat=False, dtype="float32")
        model = Model(cfg)
        params = jax.tree_util.tree_map(
            lambda x: x.astype(jnp.float32) if x.dtype == jnp.bfloat16
            else x, model.init(jax.random.PRNGKey(0)))
        tracer = Tracer()
        eng = PagedServeEngine(model, params, num_blocks=16, block_size=8,
                               max_batch=2, max_seq_len=64,
                               prefill_buckets=(16,), tracer=tracer)
        rng = np.random.default_rng(3)
        reqs = [Request(uid=i,
                        prompt=rng.integers(0, cfg.vocab_size, (9 + 3 * i,)),
                        max_new_tokens=3) for i in range(2)]
        done = eng.run(reqs, max_ticks=100)
        set_active(None)
        assert len(done) == 2 and all(r.error is None for r in done)
        return tracer

    def test_every_serving_phase_has_a_span(self, traced_run):
        spans = {e["name"] for e in traced_run.events if e["ph"] == "X"}
        for phase in ("tick", "admission", "prefill_chunk",
                      "decode_dispatch", "device_sync", "sample"):
            assert phase in spans, (phase, sorted(spans))

    def test_per_request_tracks_and_lifecycle_instants(self, traced_run):
        tracks = set(traced_run.tracks())
        assert {"req/0", "req/1"} <= tracks
        by_track = {}
        for e in traced_run.events:
            by_track.setdefault(e["track"], []).append(e["name"])
        for uid in (0, 1):
            names = by_track[req_track(uid)]
            for ev in ("submit", "admit", "first_token", "complete"):
                assert ev in names, (uid, ev, names)
            # lifecycle ordering on the request's own lane
            assert names.index("submit") < names.index("admit") \
                < names.index("first_token") < names.index("complete")

    def test_real_trace_exports_valid_chrome_json(self, traced_run):
        assert validate_chrome(to_chrome(traced_run)) == []
        assert traced_run.dropped == 0

    def test_untraced_engine_holds_null_tracer(self):
        # constructing engines is expensive; check the default wiring on
        # the scheduler level instead of building a second engine
        from repro.serve import BlockPool, Scheduler
        sched = Scheduler(BlockPool(num_blocks=4, block_size=4), rows=2,
                          buckets=(16,), max_blocks_per_seq=4)
        assert isinstance(sched.trace, NullTracer)


# ---------------------------------------------------------------------------
# metrics satellites
# ---------------------------------------------------------------------------


class TestMetricsSatellites:
    def test_histogram_extremes_exact_under_reservoir_eviction(self):
        h = Histogram(max_samples=8)
        rng = np.random.default_rng(1)
        vals = rng.uniform(1e-4, 1.0, 500)
        vals[37] = 7.5                     # true max, early: will be
        vals[11] = 1e-6                    # evicted from an 8-slot pool
        for v in vals:
            h.observe(float(v))
        s = h.summary()
        assert s["max"] == 7.5 and s["min"] == 1e-6
        assert 7.5 not in h._samples or 1e-6 not in h._samples \
            or len(h._samples) == 8
        # percentile(100) is what max used to be — the reservoir lost it
        assert h.percentile(100) <= s["max"]
        assert set(s) == {"n", "mean", "p50", "p95", "min", "max"}

    def test_histogram_empty_extremes_are_zero(self):
        s = Histogram().summary()
        assert s["min"] == 0.0 and s["max"] == 0.0 and s["n"] == 0

    def test_decode_path_counts_survive_mixed_runs(self):
        m = ServeMetrics(clock=_Clock())
        assert m.decode_path is None
        m.on_decode_step(2, 10, 20, "fused")
        assert m.decode_path == "fused"
        m.on_decode_step(1, 5, 10, "gather")
        m.on_decode_step(1, 5, 10, "fused")
        # the old last-write string would report "fused" and hide the
        # gather step entirely
        assert m.decode_path == "mixed"
        pk = m.summary()["paged_kernel"]
        assert pk["path"] == "mixed"
        assert pk["steps_by_path"] == {"fused": 2, "gather": 1}

    def test_throughput_clock_starts_at_first_admission(self):
        clk = _Clock()
        m = ServeMetrics(clock=clk)
        clk.t = 10.0                       # long idle warm-up after init
        m.on_submit(0)
        clk.t = 11.0
        m.on_admit(0)                      # clock anchors HERE
        clk.t = 11.5
        for _ in range(5):
            m.on_token(0)
        clk.t = 12.0
        # 5 tokens over 1s since first admission — not over 12s since
        # construction (which would report ~0.42 tok/s)
        assert m.throughput() == pytest.approx(5.0)
        m2 = ServeMetrics(clock=_Clock())
        assert m2.throughput() == 0.0      # nothing admitted: no div-by-0


# ---------------------------------------------------------------------------
# README glossary sync
# ---------------------------------------------------------------------------


def _summary_keys(d, prefix=""):
    keys = set()
    for k, v in d.items():
        keys.add(k)
        if isinstance(v, dict):
            keys |= _summary_keys(v)
    return keys


def test_readme_glossary_documents_every_summary_key():
    """Every key ``ServeMetrics.summary()`` emits must appear (in
    backticks) in the README "Serving metrics glossary" section, so the
    uploaded ``serve-metrics`` artifact stays self-describing.  Brace
    groups like ``kv_bytes_per_token_{fused,gathered}`` expand."""
    root = os.path.join(os.path.dirname(__file__), os.pardir)
    with open(os.path.join(root, "README.md")) as f:
        text = f.read()
    start = text.index("### Serving metrics glossary")
    section = text[start:]
    section = section[:section.index("### ", 4)]

    import re
    documented = set()
    for tok in re.findall(r"`([^`\n]+)`", section):
        m = re.fullmatch(r"(\w*)\{([\w,]+)\}(\w*)", tok)
        if m:
            documented |= {m.group(1) + mid + m.group(3)
                           for mid in m.group(2).split(",")}
        else:
            documented.add(tok)

    summary = ServeMetrics(clock=_Clock()).summary()
    missing = _summary_keys(summary) - documented
    assert not missing, (
        f"summary() keys missing from the README glossary: "
        f"{sorted(missing)} — document them in 'Serving metrics glossary'")


# ---------------------------------------------------------------------------
# perf-trajectory gate
# ---------------------------------------------------------------------------


def _bench(scalars, bench="serve", schema=traj.SCHEMA_VERSION):
    return {"schema_version": schema, "bench": bench, "scalars": scalars}


def _s(value, direction="higher", rel_tol=0.0, **bounds):
    d = {"value": value, "direction": direction, "rel_tol": rel_tol}
    d.update(bounds)
    return d


class TestTrajectoryGate:
    def test_identity_and_improvement_pass(self):
        base = _bench({"tok_s": _s(100.0, "higher", 0.1),
                       "ttft": _s(5.0, "lower", 0.1)})
        fails, rows = traj.compare(base, base)
        assert fails == [] and all(r["status"] == "ok" for r in rows)
        cur = _bench({"tok_s": _s(150.0), "ttft": _s(4.0)})
        fails, rows = traj.compare(cur, base)
        assert fails == []
        assert {r["status"] for r in rows} == {"improved"}

    def test_regression_beyond_tolerance_fails(self):
        base = _bench({"tok_s": _s(100.0, "higher", 0.1),
                       "ttft": _s(5.0, "lower", 0.1)})
        cur = _bench({"tok_s": _s(89.9), "ttft": _s(5.51)})
        fails, rows = traj.compare(cur, base)
        assert len(fails) == 2
        assert all(r["status"] == "REGRESSED" for r in rows)
        # within tolerance: both pass
        cur = _bench({"tok_s": _s(90.1), "ttft": _s(5.49)})
        assert traj.compare(cur, base)[0] == []

    def test_absolute_bounds_trump_relative_slack(self):
        base = _bench({"overhead": _s(2.0, "lower", 10.0, abs_max=5.0),
                       "speedup": _s(1.5, "higher", 0.9, abs_min=1.0)})
        cur = _bench({"overhead": _s(5.5), "speedup": _s(0.99)})
        fails, _ = traj.compare(cur, base)
        assert len(fails) == 2
        assert any("abs_max" in f for f in fails)
        assert any("abs_min" in f for f in fails)
        cur = _bench({"overhead": _s(4.9), "speedup": _s(1.01)})
        assert traj.compare(cur, base)[0] == []

    def test_missing_tracked_scalar_is_a_failure(self):
        base = _bench({"tok_s": _s(100.0), "ttft": _s(5.0, "lower")})
        cur = _bench({"tok_s": _s(100.0)})
        fails, rows = traj.compare(cur, base)
        assert len(fails) == 1 and "coverage" in fails[0]
        assert any(r["status"] == "MISSING" for r in rows)

    def test_new_scalar_reported_not_failed(self):
        base = _bench({"tok_s": _s(100.0)})
        cur = _bench({"tok_s": _s(100.0), "shiny": _s(1.0)})
        fails, rows = traj.compare(cur, base)
        assert fails == []
        assert any(r["scalar"] == "shiny" and "new" in r["status"]
                   for r in rows)

    def test_bench_name_mismatch_fails(self):
        fails, _ = traj.compare(_bench({}, bench="serve"),
                                _bench({}, bench="kernels"))
        assert fails and "mismatch" in fails[0]

    def test_baselines_gate_fields_win(self):
        # a regressing run cannot loosen its own tolerance: the current
        # file's rel_tol/direction are ignored
        base = _bench({"tok_s": _s(100.0, "higher", 0.0)})
        cur = _bench({"tok_s": _s(50.0, "higher", 0.99)})
        fails, _ = traj.compare(cur, base)
        assert len(fails) == 1

    def test_main_exit_codes_and_schema_gate(self, tmp_path):
        good = tmp_path / "good.json"
        bad = tmp_path / "bad.json"
        basep = tmp_path / "base.json"
        base = _bench({"tok_s": _s(100.0, "higher", 0.1)})
        basep.write_text(json.dumps(base))
        good.write_text(json.dumps(_bench({"tok_s": _s(99.0)})))
        bad.write_text(json.dumps(_bench({"tok_s": _s(10.0)})))
        assert traj.main([str(good), str(basep)]) == 0
        assert traj.main([str(bad), str(basep)]) == 1
        stale = tmp_path / "stale.json"
        stale.write_text(json.dumps(_bench({}, schema=0)))
        with pytest.raises(SystemExit):
            traj.main([str(stale), str(basep)])

    def test_committed_baselines_load_and_self_compare(self):
        root = os.path.join(os.path.dirname(__file__), os.pardir)
        for name in ("BENCH_serve.json", "BENCH_kernels.json"):
            path = os.path.join(root, "benchmarks", "baselines", name)
            data = traj.load(path)
            fails, rows = traj.compare(data, data)
            assert fails == [] and rows, name
