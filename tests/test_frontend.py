"""Asyncio frontend lifecycle tests (``repro.serve.frontend``).

No pytest-asyncio: every test is a plain sync function that drives its
own event loop with ``asyncio.run`` — the frontend is single-threaded
by design, so a loop per test is exact and hermetic.  Deadline tests
inject a manually-advanced fake clock into the ENGINE (the frontend
stamps deadlines on the engine clock), so expiry is deterministic and
no test ever sleeps.
"""
import asyncio

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_reduced
from repro.models import Model
from repro.serve import (AsyncServeFrontend, FrontendClosedError,
                         PagedServeEngine, QueueFullError)

RNG = jax.random.PRNGKey(0)


class _ManualClock:
    """Engine clock that only moves when told to."""

    def __init__(self):
        self.t = 0.0

    def advance(self, dt):
        self.t += dt

    def __call__(self):
        return self.t


@pytest.fixture(scope="module")
def model_and_params():
    cfg = get_reduced("opt_6_7b").replace(remat=False, dtype="float32",
                                          capacity_factor=8.0)
    m = Model(cfg)
    params = jax.tree_util.tree_map(
        lambda x: x.astype(jnp.float32) if x.dtype == jnp.bfloat16 else x,
        m.init(RNG))
    return m, params


def _engine(model_and_params, clock=None, **over):
    m, params = model_and_params
    kw = dict(num_blocks=16, block_size=8, max_batch=2, max_seq_len=64,
              prefill_buckets=(16,))
    kw.update(over)
    if clock is not None:
        kw["clock"] = clock
    return PagedServeEngine(m, params, **kw)


def _prompt(n, seed=0):
    # 256 == the reduced configs' vocab size (any smaller bound works)
    return np.random.default_rng(seed).integers(0, 256, (n,))


def test_stream_yields_every_token_in_order(model_and_params):
    """Async iteration over a handle delivers exactly the request's
    out_tokens, in order, for greedy and seeded-sampling requests."""
    eng = _engine(model_and_params)
    fe = AsyncServeFrontend(eng)

    async def go():
        h1 = await fe.submit(_prompt(5), max_new_tokens=4)
        h2 = await fe.submit(_prompt(9, seed=1), max_new_tokens=4,
                             temperature=0.8, top_k=8, seed=7)

        async def consume(h):
            return [tok async for tok in h]

        drain = asyncio.ensure_future(fe.drain())
        t1, t2 = await asyncio.gather(consume(h1), consume(h2))
        await drain
        return h1, h2, t1, t2

    h1, h2, t1, t2 = asyncio.run(go())
    assert h1.done and h2.done and h1.error is None and h2.error is None
    assert t1 == h1.out_tokens and len(t1) == 4
    assert t2 == h2.out_tokens and len(t2) == 4
    assert (await_result := h1.request).done    # wait() returned the req
    assert await_result.uid == h1.uid
    eng.pool.check()
    assert eng.pool.free_blocks == eng.pool.capacity


def test_bounded_queue_rejects_with_typed_error(model_and_params):
    """The admission queue sheds load with QueueFullError (carrying the
    bound) instead of buffering unboundedly; already-accepted requests
    still complete."""
    eng = _engine(model_and_params)
    fe = AsyncServeFrontend(eng, max_queue=2)

    async def go():
        h1 = await fe.submit(_prompt(5), max_new_tokens=3)
        h2 = await fe.submit(_prompt(6, seed=1), max_new_tokens=3)
        with pytest.raises(QueueFullError) as ei:
            fe.submit_nowait(_prompt(7, seed=2), max_new_tokens=3)
        assert ei.value.limit == 2
        await fe.drain()
        # queue drained: submits are accepted again
        h3 = await fe.submit(_prompt(7, seed=2), max_new_tokens=3)
        await fe.drain()
        return h1, h2, h3

    h1, h2, h3 = asyncio.run(go())
    assert all(h.done and h.error is None for h in (h1, h2, h3))
    assert all(len(h.out_tokens) == 3 for h in (h1, h2, h3))


def test_cancellation_frees_blocks_and_prefix_refs(model_and_params):
    """Cancelling a mid-decode request releases its pool blocks AND its
    prefix-cache references: with the cache on and every prompt sharing
    a prefix, the pool must balance back to capacity after the cache is
    cleared — a leaked adopted-block refcount would pin blocks."""
    eng = _engine(model_and_params, prefix_cache=True)
    fe = AsyncServeFrontend(eng)
    prefix = _prompt(16, seed=3)

    async def go():
        hs = [await fe.submit(np.concatenate([prefix, _prompt(3 + i,
                                                              seed=4 + i)]),
                              max_new_tokens=12) for i in range(3)]
        # tick until the victim has streamed a couple of tokens
        for _ in range(200):
            if len(hs[1].out_tokens) >= 2:
                break
            fe.step()
            await asyncio.sleep(0)
        assert len(hs[1].out_tokens) >= 2
        assert hs[1].cancel()
        await fe.drain()
        return hs

    hs = asyncio.run(go())
    victim, rest = hs[1], [hs[0], hs[2]]
    assert victim.done and victim.error == "cancelled"
    assert 0 < len(victim.out_tokens) < 12
    assert all(h.error is None and len(h.out_tokens) == 12 for h in rest)
    assert eng.metrics.counters["cancelled"] == 1
    eng.pool.check()
    eng.prefix.clear()
    assert eng.pool.free_blocks == eng.pool.capacity


def test_deadline_expiry_with_fake_clock(model_and_params):
    """Deadlines are absolute times on the engine's injectable clock: a
    queued request and a running request both expire the tick after the
    fake clock passes their deadline, free their blocks, and finish
    their handles with error="deadline"."""
    clk = _ManualClock()
    eng = _engine(model_and_params, clock=clk, max_batch=1)
    fe = AsyncServeFrontend(eng)

    async def go():
        run = await fe.submit(_prompt(5), max_new_tokens=20,
                              deadline_ms=100.0)
        queued = await fe.submit(_prompt(6, seed=1), max_new_tokens=4,
                                 deadline_ms=50.0)   # max_batch=1: waits
        for _ in range(4):                           # clock frozen: no expiry
            fe.step()
            await asyncio.sleep(0)
        assert not run.done and not queued.done
        assert run.out_tokens
        clk.advance(0.075)                           # past queued's 50ms only
        fe.step()
        assert queued.done and queued.error == "deadline"
        assert queued.out_tokens == []               # never admitted
        clk.advance(0.050)                           # past run's 100ms
        fe.step()
        eng.flush()
        fe._reap()
        assert run.done and run.error == "deadline"
        await run.wait()                             # must not hang
        return run, queued

    run, queued = asyncio.run(go())
    assert 0 < len(run.out_tokens) < 20
    assert eng.metrics.counters["deadline_expired"] == 2
    eng.pool.check()
    assert eng.pool.free_blocks == eng.pool.capacity


def test_close_unblocks_live_handles(model_and_params):
    """close() fails still-live requests with error="shutdown" so no
    stream consumer or wait()-er hangs, and rejects later submits."""
    eng = _engine(model_and_params)
    fe = AsyncServeFrontend(eng)

    async def go():
        h = await fe.submit(_prompt(5), max_new_tokens=30)
        fe.step()
        fe.close()
        await h.wait()
        toks = [tok async for tok in h]              # stream terminates
        with pytest.raises(FrontendClosedError):
            fe.submit_nowait(_prompt(4, seed=9))
        return h, toks

    h, toks = asyncio.run(go())
    assert h.done and h.error == "shutdown"
    assert toks == h.out_tokens
    eng.pool.check()
    assert eng.pool.free_blocks == eng.pool.capacity


def test_serve_forever_with_concurrent_clients(model_and_params):
    """The launcher's shape, end to end: serve_forever as a task, N
    client coroutines submitting and consuming concurrently, mixed
    deadlines via the real clock (generous enough to never fire), clean
    shutdown."""
    eng = _engine(model_and_params, max_batch=3)
    fe = AsyncServeFrontend(eng, idle_sleep=0.0)

    async def client(i):
        h = await fe.submit(_prompt(4 + i, seed=20 + i), max_new_tokens=4,
                            deadline_ms=(60_000.0 if i % 2 else None))
        toks = [tok async for tok in h]
        return h, toks

    async def go():
        loop = asyncio.ensure_future(fe.serve_forever())
        out = await asyncio.gather(*(client(i) for i in range(5)))
        fe.close()
        await loop
        return out

    out = asyncio.run(go())
    for h, toks in out:
        assert h.done and h.error is None
        assert toks == h.out_tokens and len(toks) == 4
    assert eng.metrics.counters["completed"] == 5
    eng.pool.check()
    assert eng.pool.free_blocks == eng.pool.capacity
