"""End-to-end training driver with fault tolerance.

Default preset trains a small LM for a few hundred steps on CPU with
checkpoint/restart enabled; ``--preset 100m`` is the ~100M-parameter
configuration for a real accelerator (same code path).

    PYTHONPATH=src python examples/train_lm.py --steps 200
    PYTHONPATH=src python examples/train_lm.py --inject-failure 120
"""
import argparse

import jax

from repro.configs import get_reduced
from repro.data.pipeline import SyntheticLM
from repro.models import Model
from repro.optim import adamw
from repro.train.trainer import Trainer, TrainConfig

PRESETS = {
    # ~3M params: a-few-minutes CPU run
    "tiny": dict(n_layers=4, d_model=256, n_heads=8, n_kv_heads=8,
                 head_dim=32, d_ff=1024, vocab_size=2048, max_seq_len=256),
    # ~100M params: real-accelerator scale, same code path
    "100m": dict(n_layers=12, d_model=768, n_heads=12, n_kv_heads=12,
                 head_dim=64, d_ff=3072, vocab_size=32768, max_seq_len=1024),
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", default="tiny", choices=PRESETS)
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    ap.add_argument("--inject-failure", type=int, default=None,
                    help="simulate a node failure at this step (recovery demo)")
    ap.add_argument("--grad-compression", action="store_true")
    args = ap.parse_args()

    cfg = get_reduced("opt_6_7b").replace(remat=False, scan_layers=False,
                                          **PRESETS[args.preset])
    model = Model(cfg)
    print(f"[train_lm] {cfg.name} preset={args.preset}: "
          f"{model.n_params():,} params")

    pipe = SyntheticLM(vocab_size=cfg.vocab_size, seq_len=args.seq_len,
                       global_batch=args.batch, seed=0)
    tcfg = TrainConfig(steps=args.steps, ckpt_every=50,
                       ckpt_dir=args.ckpt_dir, log_every=20,
                       grad_compression=args.grad_compression)
    ocfg = adamw.AdamWConfig(lr=1e-3, warmup_steps=20,
                             total_steps=args.steps, weight_decay=0.01)
    trainer = Trainer(model, ocfg, tcfg)
    state, hist = trainer.run(pipe, inject_failure_at=args.inject_failure)
    print(f"[train_lm] done: step {int(state['step'])}, "
          f"loss {hist[0]['loss']:.3f} -> {hist[-1]['loss']:.3f}")
    assert hist[-1]["loss"] < hist[0]["loss"]
    print("train_lm OK")


if __name__ == "__main__":
    main()
