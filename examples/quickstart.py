"""Quickstart: BCQ-quantize weights, run LUT-based FP-INT GEMM, verify.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import bcq
from repro.core.lut_gemm import bcq_apply
from repro.kernels.lut_gemm import lut_gemm
from repro.models import Model
from repro.configs import get_reduced
from repro.quant import QuantSpec, quantize_model


def main():
    rng = np.random.default_rng(0)

    # --- 1. one weight matrix ------------------------------------------
    W = jnp.array(rng.normal(size=(512, 1024)).astype(np.float32))
    x = jnp.array(rng.normal(size=(4, 1024)).astype(np.float32))

    w_bcq = bcq.quantize(W, bits=3, group_size=128, iters=5)     # non-uniform
    w_rtn = bcq.from_uniform(W, bits=3, group_size=128)          # uniform->BCQ
    dense_bytes = W.size * 2                                     # bf16
    print(f"dense bf16: {dense_bytes/1e6:.2f} MB  ->  BCQ-3bit: "
          f"{w_bcq.nbytes()/1e6:.2f} MB  ({dense_bytes/w_bcq.nbytes():.1f}x)")
    for name, wq in [("BCQ (alternating)", w_bcq), ("RTN-as-BCQ", w_rtn)]:
        err = float(jnp.mean((bcq.dequantize(wq) - W) ** 2))
        print(f"  {name:18s} weight MSE = {err:.5f}")

    # --- 2. the three execution paths agree -----------------------------
    y_dense = bcq_apply(x, w_bcq, "dense")       # dequant + matmul (FPE), f32
    y_xla = bcq_apply(x, w_bcq, "bcq_xla")       # packed XLA path, bf16 compute
    y_pallas = lut_gemm(x, w_bcq, interpret=True)  # the FIGLUT kernel
    scale = float(jnp.abs(y_dense).max())
    print(f"bcq_xla(bf16) vs dense rel err: "
          f"{float(jnp.abs(y_xla - y_dense).max())/scale:.2e} (bf16-compute)")
    print(f"pallas kernel vs dense rel err: "
          f"{float(jnp.abs(y_pallas - y_dense).max())/scale:.2e}")

    # --- 3. whole model -------------------------------------------------
    cfg = get_reduced("opt_6_7b")
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = {"tokens": jnp.array(rng.integers(0, cfg.vocab_size, (2, 32)))}
    loss_fp = float(model.loss_fn(params, batch))
    spec = QuantSpec(bits=4, group_size=64, iters=3, backend="bcq_xla")
    qparams, manifest = quantize_model(params, spec, model.axes())
    print(f"[quickstart] {manifest.summary()}")
    model_q = Model(cfg.replace(quant=spec))
    loss_q = float(model_q.loss_fn(qparams, batch))
    print(f"model loss: fp32 {loss_fp:.4f} vs BCQ-4bit {loss_q:.4f}")
    print("quickstart OK")


if __name__ == "__main__":
    main()
