"""End-to-end driver (the paper's deployment shape): quantize an LM to
sub-4-bit BCQ and serve batched requests through the paged-KV
continuous-batching engine on the LUT/BCQ execution path, streaming
tokens as they decode.

    PYTHONPATH=src python examples/serve_quantized.py [--bits 3] [--requests 8]
"""
import argparse
import time

import jax
import numpy as np

from repro.configs import get_reduced
from repro.models import Model
from repro.quant import QuantSpec, quantize_model
from repro.serve import PagedServeEngine, Request


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--bits", type=float, default=None,
                    help="fractional (e.g. 2.4) -> mixed precision; "
                         "default 3 (ternary: fixed 2 planes)")
    ap.add_argument("--format", default="bcq",
                    choices=["bcq", "rtn", "ternary"])
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--arch", default="opt_6_7b")
    args = ap.parse_args()

    cfg = get_reduced(args.arch).replace(max_seq_len=512)
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    print(f"[serve] arch={cfg.name} (reduced), {model.n_params():,} params")

    # explicit --bits passes through (ternary raises on a conflicting
    # value); unset -> 3-bit, or the format default
    bits = args.bits if args.bits is not None else \
        (None if args.format == "ternary" else 3)
    spec = QuantSpec(format=args.format, bits=bits, group_size=64, iters=3)
    t0 = time.time()
    qparams, manifest = quantize_model(params, spec, model.axes())
    print(f"[serve] {spec.describe()} in {time.time()-t0:.1f}s")
    print(f"[serve] {manifest.summary()}")

    model_q = Model(cfg.replace(quant=spec))
    streamed = {}

    def on_token(tok, req):
        streamed.setdefault(req.uid, []).append(tok)

    engine = PagedServeEngine(model_q, qparams, num_blocks=24, block_size=8,
                              max_batch=4, max_seq_len=128,
                              prefill_buckets=(16, 32))

    rng = np.random.default_rng(0)
    reqs = [Request(uid=i,
                    prompt=rng.integers(0, cfg.vocab_size, size=(rng.integers(5, 20),)),
                    max_new_tokens=args.max_new,
                    temperature=0.0 if i % 2 == 0 else 0.8,
                    on_token=on_token)
            for i in range(args.requests)]
    t0 = time.time()
    done = engine.run(reqs, max_ticks=1000)
    dt = time.time() - t0
    total_tokens = sum(len(r.out_tokens) for r in done)
    print(f"[serve] {len(done)}/{len(reqs)} requests done, "
          f"{total_tokens} tokens in {dt:.1f}s "
          f"({total_tokens/dt:.1f} tok/s across {engine.ticks} ticks)")
    s = engine.metrics.summary()
    print(f"[serve] ttft p50={s['ttft_s']['p50']*1e3:.1f}ms  "
          f"pool occupancy peak={s['occupancy']['peak']:.2f}  "
          f"preempted={s['counters']['preempted']}")
    for r in done[:3]:
        print(f"  req {r.uid}: prompt[{len(r.prompt)}] -> {r.out_tokens}")
    assert len(done) == len(reqs)
    assert all(streamed[r.uid] == r.out_tokens for r in done), \
        "streaming callbacks must see every token in order"
    engine.pool.check()
    print("serve_quantized OK")


if __name__ == "__main__":
    main()
