"""Mixed-precision BCQ demo (paper Fig 17): sensitivity-based bit
allocation at a 2.4-bit average, vs uniform 2/3/4-bit.

    PYTHONPATH=src python examples/mixed_precision_demo.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_reduced
from repro.models import Model
from repro.quant import QuantSpec, quantize_model


def main():
    cfg = get_reduced("opt_6_7b").replace(remat=False)
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    batch = {"tokens": jnp.array(rng.integers(0, cfg.vocab_size, (4, 64)))}
    loss_fp = float(model.loss_fn(params, batch))

    rows = []
    for name, spec in [
        ("uniform-2bit", QuantSpec(bits=2, group_size=32, iters=3)),
        ("mixed-2.4bit", QuantSpec(bits=2.4, group_size=32, iters=3)),
        ("uniform-3bit", QuantSpec(bits=3, group_size=32, iters=3)),
        ("uniform-4bit", QuantSpec(bits=4, group_size=32, iters=3)),
    ]:
        qp, manifest = quantize_model(params, spec, model.axes())
        model_q = Model(cfg.replace(quant=spec))
        loss = float(model_q.loss_fn(qp, batch))
        rows.append((name, loss))
        print(f"[mixed] {name:16s} loss={loss:.4f} (fp {loss_fp:.4f})  "
              f"avg {manifest.avg_plane_bits:.2f} plane-bits")
        if name == "mixed-2.4bit":
            for l in manifest.layers:
                print(f"    {l['plane_bits']}-bit  {l['path']}")
    # mixed 2.4 should sit between uniform 2 and uniform 3
    d = dict(rows)
    assert d["mixed-2.4bit"] <= d["uniform-2bit"] + 1e-3
    print("mixed_precision_demo OK")


if __name__ == "__main__":
    main()
