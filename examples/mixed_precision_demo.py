"""Mixed-precision BCQ demo (paper Fig 17): sensitivity-based bit
allocation at a 2.4-bit average, vs uniform 2/3/4-bit.

    PYTHONPATH=src python examples/mixed_precision_demo.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_reduced
from repro.core.mixed_precision import allocate_bits, average_bits
from repro.models import Model
from repro.quantize import quantize_model, collect_linears


def main():
    cfg = get_reduced("opt_6_7b").replace(remat=False)
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    batch = {"tokens": jnp.array(rng.integers(0, cfg.vocab_size, (4, 64)))}
    loss_fp = float(model.loss_fn(params, batch))

    lin = collect_linears(params)
    bit_map = allocate_bits(lin, target_avg_bits=2.4, candidates=(2, 3, 4),
                            group_size=32)
    avg = average_bits(bit_map, lin)
    print(f"[mixed] allocated {len(bit_map)} layers, avg {avg:.2f} bits:")
    for k, b in sorted(bit_map.items()):
        print(f"    {b}-bit  {k}")

    model_q = Model(cfg.replace(gemm_backend="bcq_xla"))
    rows = []
    for name, kwargs in [
        ("uniform-2bit", dict(bits=2)),
        (f"mixed-{avg:.1f}bit", dict(bits=2, bit_map=bit_map)),
        ("uniform-3bit", dict(bits=3)),
        ("uniform-4bit", dict(bits=4)),
    ]:
        qp = quantize_model(params, model.axes(), method="bcq", group_size=32,
                            iters=3, **kwargs)
        loss = float(model_q.loss_fn(qp, batch))
        rows.append((name, loss))
        print(f"[mixed] {name:16s} loss={loss:.4f} (fp {loss_fp:.4f})")
    # mixed 2.4 should sit between uniform 2 and uniform 3
    d = dict(rows)
    assert d[f"mixed-{avg:.1f}bit"] <= d["uniform-2bit"] + 1e-3
    print("mixed_precision_demo OK")


if __name__ == "__main__":
    main()
